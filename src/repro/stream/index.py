"""MedoidIndex — exact medoid maintenance under point churn (DESIGN.md §15).

The elimination machinery is stateless: a solve starts from zero bounds
and pays the full `O(N^{3/2})` element bill. But its persisted artifact
(:class:`~repro.core.solve_state.SolveState`, format 2) carries exactly
what an *index* needs — per-row lower bounds, the incumbent, and the
``esum`` energy cache of every computed pivot's raw column sum. This
module turns that artifact into a maintained structure:

* **churn ops** (:meth:`MedoidIndex.insert` / :meth:`~MedoidIndex.delete`
  / :meth:`~MedoidIndex.update`) delta-adjust host-side float64 ledgers
  with one distance pass over the touched rows — ``O(k·N)`` scalar
  distances for ``k`` churned points, i.e. ``k`` row-equivalents in the
  unified element currency;
* **query** (:meth:`MedoidIndex.query`) is where repair happens, lazily:
  elect an incumbent from the energy cache (canonically recomputed with
  the engine's exact arithmetic), re-admit only the rows whose repaired
  bound fell under it, and finish them with
  :func:`~repro.core.pipelined.resume_with_survivors` — the same
  segmented ladder loop a fresh solve runs, now over a few rows instead
  of N.

Exactness bar (property-tested in ``tests/test_stream.py``): after any
churn sequence, ``query()`` returns bit-for-bit the ``(index, energy,
certificate)`` a fresh *pipelined* solve on the current point set would
— the engine the repair path is built from. (Other engines return the
same medoid with last-ulp energy differences from their different
summation geometry, so "bit-for-bit" is only meaningful against one.)

The float story, in one place (full derivation in DESIGN.md §15): the
ledgers hold *raw* sums ``S(i)`` (exact f64 deltas on top of canonically
computed f32 baselines) and raw-scale lower bounds ``L(i) <= S(i)``.
Every comparison that decides the answer happens in the engine's own
float32 arithmetic on canonically recomputed values; the f64 ledger only
*shortlists*, with a relative slack ``_margin(n)`` that dominates the
canonical f32 summation error (``~(n/48)·eps32``). Bound admission
deflates by the same margin — under-elimination costs a few extra
computed rows, never the answer. Degenerate regimes the margins cannot
cover (zero incumbent energy, emptied cache, tiny N) fall back to a
full re-solve, which is itself the parity oracle.

Mass invalidation is deliberately NOT a fallback trigger. The first
delete after a build tips the whole *bound cliff* — rows the ladder
compacted away all carry the same ``L/n == e_cl`` bound, so they cross
the incumbent together (DESIGN.md §15). Routing that slab through
:func:`resume_with_survivors` costs about one fresh solve *once*, but
the engine's elimination leaves graded per-row bounds and exact cached
energies behind, so steady-state churn invalidates ~1 row per op. A
full re-solve would cost the same now and rebuild the cliff, paying
fresh-solve price on *every* future query — strictly worse.
"""
from __future__ import annotations

import functools
import shutil
import tempfile
from pathlib import Path

import numpy as np

import jax
import jax.numpy as jnp

from repro.api.metrics import require_metric
from repro.checkpoint.checkpoint import Checkpointer
from repro.core.distances import chunked_rowsum, pairwise, sq_norms
from repro.core.pipelined import (LADDER_MIN, _trimed_pipelined,
                                  resume_with_survivors)
from repro.core.solve_state import (_FORMAT, PHASE_FULL, SolveState,
                                    SolveStateMismatch)
from repro.core.trimed import MedoidResult
from repro.kernels import ops as _ops

#: below this the repair machinery is pure overhead — re-solve
TINY_N = 8


def _margin(n: int) -> float:
    """Relative slack covering canonical-f32 vs f64-ledger drift: the
    chunked row sum accumulates ``~(n/48 + 48)`` f32 roundings, so 32
    eps per 48-column chunk is a ~25x safety factor."""
    return 32.0 * float(np.finfo(np.float32).eps) * max(n / 48.0, 2.0)


@functools.lru_cache(maxsize=64)
def _engine_div(n: int):
    """f32 division by the Python-int constant ``n`` with the ENGINE's
    bits. Inside jit XLA rewrites divide-by-constant into a reciprocal
    multiply, which is NOT correctly rounded — it can land 1 ulp away
    from numpy's host divide. Election must reproduce the engine's
    bits, so the ``e_sums / n`` step runs through this jitted path."""
    return jax.jit(lambda e: e / n)


def _host_pairwise(a: np.ndarray, b: np.ndarray, metric: str) -> np.ndarray:
    """Float64 host mirror of :func:`repro.core.distances.pairwise` for
    the ledger delta passes. Small formula-level drift vs the device f32
    values is fine — ledgers only shortlist, inside ``_margin``."""
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    if metric in ("l2", "sqeuclidean"):
        d2 = ((a[:, None, :] - b[None, :, :]) ** 2).sum(-1)
        d2 = np.maximum(d2, 0.0)
        return d2 if metric == "sqeuclidean" else np.sqrt(d2)
    if metric == "l1":
        return np.abs(a[:, None, :] - b[None, :, :]).sum(-1)
    if metric == "cosine":
        an = a / np.maximum(np.linalg.norm(a, axis=-1, keepdims=True),
                            1e-30)
        bn = b / np.maximum(np.linalg.norm(b, axis=-1, keepdims=True),
                            1e-30)
        return np.maximum(1.0 - an @ bn.T, 0.0)
    # fall back to the registry metric, evaluated rowwise on host
    m = require_metric(metric, need_triangle=True, caller="MedoidIndex")
    return np.asarray(m.pairwise_fn(jnp.asarray(a), jnp.asarray(b)),
                      np.float64)


class MedoidIndex:
    """An exact medoid index over a mutable point set.

    Build with :meth:`from_data` (runs one full solve and captures its
    :class:`SolveState` into the ledgers) or :meth:`load` (a previously
    :meth:`save`-d index). Churn with :meth:`insert` / :meth:`delete` /
    :meth:`update`; :meth:`query` returns a
    :class:`~repro.core.trimed.MedoidResult` whose ``n_computed`` counts
    only this query's repair elements (delta passes since the last
    query + election recomputes + engine rows). ``index.last_plan``
    carries the :class:`repro.api.planner.Plan` with the repair
    accounting (``plan.params["repair"]``).

    ``checkpoint`` (a directory) makes repairs themselves resumable: a
    repair killed mid-ladder (DESIGN.md §13 fault injection) resumes
    bit-identically on the next ``query()`` — ledgers commit only after
    the engine succeeds, so the retried election reproduces the exact
    inputs the killed repair checkpointed under.
    """

    def __init__(self, X, S, L, computed, *, metric="l2", block=128,
                 ladder_min=LADDER_MIN, use_kernels=False, interpret=None,
                 checkpoint=None, metrics=None, trace=None, epoch=0):
        require_metric(metric, need_triangle=True, caller="MedoidIndex")
        self.X = np.asarray(X, np.float32)
        if self.X.ndim != 2:
            raise ValueError("MedoidIndex needs (N, d) row vectors")
        self._S = np.asarray(S, np.float64).copy()
        self._L = np.asarray(L, np.float64).copy()
        self._computed = np.asarray(computed, bool).copy()
        self.metric = metric
        self.block = int(block)
        self.ladder_min = int(ladder_min)
        self.use_kernels = bool(use_kernels)
        self.interpret = interpret
        self.checkpoint = Path(checkpoint) if checkpoint else None
        self.trace = trace
        self._epoch = int(epoch)          # churn ops absorbed, ever
        self._dirty = True
        self._last: MedoidResult | None = None
        self.last_plan = None
        self.stats = {"ops": 0, "repairs": 0, "full_resolves": 0,
                      "invalidated": 0, "elements_total": 0.0,
                      "elements_delta": 0.0, "elements_election": 0.0,
                      "elements_engine": 0.0}
        self._pending_ops = 0             # churn ops since last query
        self._pending_elements = 0.0      # their delta-pass elements
        self._mx = None
        if metrics is not None:
            from repro.obs.metrics import stream_metrics
            self._mx = stream_metrics(metrics)

    def bind_metrics(self, registry) -> None:
        """(Re)bind the stream instrument family onto ``registry`` —
        how a server adopts an index into its own scrape endpoint."""
        from repro.obs.metrics import stream_metrics
        self._mx = stream_metrics(registry)

    # ------------------------------------------------------ constructors
    @classmethod
    def from_data(cls, X, **cfg) -> "MedoidIndex":
        """Solve the initial set and capture the state as the index."""
        X = np.asarray(X, np.float32)
        n = X.shape[0]
        idx = cls(X, np.zeros(n), np.zeros(n), np.zeros(n, bool), **cfg)
        if n:          # empty index: first insert+query solves it
            idx._full_resolve()
        return idx

    # ---------------------------------------------------------- persist
    _PERSIST_KIND = "stream_index"

    def _fingerprint(self) -> dict:
        return {"kind": self._PERSIST_KIND, "format": _FORMAT,
                "metric": self.metric, "block": self.block,
                "ladder_min": self.ladder_min,
                "use_kernels": self.use_kernels,
                "d": int(self.X.shape[1])}

    def save(self, directory) -> None:
        """Persist the index (points + ledgers) for :meth:`load`."""
        ck = Checkpointer(str(directory))
        ck.save(self._epoch,
                [self.X, self._S, self._L, self._computed],
                extra_meta={"stream_index": self._fingerprint()})

    @classmethod
    def load(cls, directory, **cfg) -> "MedoidIndex":
        """Restore a :meth:`save`-d index. Refuses (``SolveStateMismatch``)
        a snapshot written under a different config or ledger format —
        resuming it could silently break the exactness contract."""
        step, leaves, meta = Checkpointer(str(directory)).load()
        info = (meta.get("extra") or {}).get("stream_index")
        if info is None:
            raise SolveStateMismatch(
                f"checkpoint step_{step} in {directory} is not a "
                "stream-index snapshot")
        X, S, L, computed = leaves
        idx = cls(X, S, L, computed,
                  metric=info["metric"], block=info["block"],
                  ladder_min=info["ladder_min"],
                  use_kernels=info["use_kernels"], epoch=step, **cfg)
        want = idx._fingerprint()
        if info != want:
            diff = sorted(k for k in set(info) | set(want)
                          if info.get(k) != want.get(k))
            raise SolveStateMismatch(
                "stream-index snapshot was written under a different "
                f"configuration or ledger format (differing keys: {diff})")
        return idx

    # ------------------------------------------------------------ churn
    @property
    def n(self) -> int:
        return self.X.shape[0]

    def _absorb(self, op: str, k: int, elements: float) -> None:
        self._epoch += 1
        self._dirty = True
        self._pending_ops += 1
        self._pending_elements += elements
        self.stats["ops"] += 1
        self.stats["elements_delta"] += elements
        self.stats["elements_total"] += elements
        if self._mx:
            self._mx["ops"].inc(op=op)

    def insert(self, rows) -> None:
        """Append ``rows`` (gets indices ``n..n+k-1``). One delta pass
        seeds the new rows' *exact* energies — subsuming the weaker
        pivot-seeded bounds, see DESIGN.md §15 — and shifts every
        existing ledger entry by its new columns."""
        rows = np.atleast_2d(np.asarray(rows, np.float32))
        if rows.shape[1] != self.X.shape[1]:
            raise ValueError(
                f"insert rows have d={rows.shape[1]}, index has "
                f"d={self.X.shape[1]}")
        k, n = rows.shape[0], self.n
        X_new = np.concatenate([self.X, rows])
        D = _host_pairwise(rows, X_new, self.metric)        # (k, n+k)
        col = D[:, :n].sum(axis=0)
        self.X = X_new
        self._S = np.concatenate([self._S + col, D.sum(axis=1)])
        self._L = np.concatenate([np.maximum(self._L + col, 0.0),
                                  D.sum(axis=1)])
        self._computed = np.concatenate([self._computed,
                                         np.ones(k, bool)])
        self._absorb("insert", k, float(k))

    def delete(self, idx) -> None:
        """Remove the rows at positions ``idx`` (later rows shift down,
        preserving relative order). One delta pass subtracts the removed
        columns from every kept ledger entry."""
        idx = np.atleast_1d(np.asarray(idx, np.int64))
        n = self.n
        if idx.size == 0:
            return
        if np.unique(idx).size != idx.size:
            raise ValueError("delete positions must be unique")
        if idx.min() < 0 or idx.max() >= n:
            raise ValueError(f"delete position out of range for n={n}")
        D = _host_pairwise(self.X[idx], self.X, self.metric)  # (k, n)
        col = D.sum(axis=0)
        self.X = np.delete(self.X, idx, axis=0)
        self._S = np.delete(self._S - col, idx)
        self._L = np.delete(np.maximum(self._L - col, 0.0), idx)
        self._computed = np.delete(self._computed, idx)
        self._absorb("delete", idx.size, float(idx.size))

    def update(self, idx, rows) -> None:
        """Replace the rows at positions ``idx`` in place. Two delta
        passes (old columns out, new columns in); the replaced rows get
        exact energies like inserts."""
        idx = np.atleast_1d(np.asarray(idx, np.int64))
        rows = np.atleast_2d(np.asarray(rows, np.float32))
        n = self.n
        if idx.size != rows.shape[0]:
            raise ValueError("update needs one row per position")
        if np.unique(idx).size != idx.size:
            raise ValueError("update positions must be unique")
        if idx.size and (idx.min() < 0 or idx.max() >= n):
            raise ValueError(f"update position out of range for n={n}")
        if rows.shape[1] != self.X.shape[1]:
            raise ValueError("update rows have the wrong width")
        D_old = _host_pairwise(self.X[idx], self.X, self.metric)
        self.X = self.X.copy()
        self.X[idx] = rows
        D_new = _host_pairwise(self.X[idx], self.X, self.metric)
        delta = (D_new - D_old).sum(axis=0)
        others = np.ones(n, bool)
        others[idx] = False
        self._S[others] += delta[others]
        self._L[others] = np.maximum(self._L[others] + delta[others], 0.0)
        self._S[idx] = D_new.sum(axis=1)
        self._L[idx] = self._S[idx]
        self._computed[idx] = True
        self._absorb("update", idx.size, float(2 * idx.size))

    # ------------------------------------------------- canonical recompute
    def _canonical_esums(self, rows_idx: np.ndarray) -> np.ndarray:
        """Raw f32 column sums of the given rows with the ENGINE's exact
        arithmetic — same ``pairwise`` shapes, same fixed reduction
        geometry — so the returned bits equal what a fresh solve's round
        loop computes for those rows (rowwise-independent, see
        ``core/distances.py``)."""
        X = jnp.asarray(self.X)
        idx = jnp.asarray(rows_idx, jnp.int32)
        xb = jnp.take(X, idx, axis=0)
        if self.use_kernels:
            e = _ops.block_energies(xb, X, metric=self.metric,
                                    interpret=self.interpret)
        else:
            x_sq = (sq_norms(X) if self.metric in ("l2", "sqeuclidean")
                    else jnp.zeros(self.n, X.dtype))
            e = chunked_rowsum(pairwise(xb, X, self.metric,
                                        a_sq=jnp.take(x_sq, idx),
                                        b_sq=x_sq))
        return np.asarray(e)

    def _elect(self):
        """Lowest-index row achieving the exact f32 minimum energy over
        the computed cache. The f64 ledger shortlists within
        ``_margin``; the shortlist is recomputed canonically and
        committed back (keeps ledger drift from compounding)."""
        n = self.n
        comp = self._computed
        e_ledger = self._S / n
        emin = float(e_ledger[comp].min())
        cand = np.flatnonzero(
            comp & (e_ledger <= emin * (1.0 + _margin(n)) + 1e-300))
        e_sums = self._canonical_esums(cand)
        e32 = np.asarray(_engine_div(n)(jnp.asarray(e_sums)))
        e_cl = e32.min()
        m_cl = int(cand[np.flatnonzero(e32 == e_cl)[0]])
        self._S[cand] = e_sums.astype(np.float64)
        self._L[cand] = self._S[cand]
        self.stats["elements_election"] += cand.size
        self.stats["elements_total"] += cand.size
        return np.float32(e_cl), m_cl, int(cand.size)

    # ------------------------------------------------------------ query
    def query(self, *, trace=None) -> MedoidResult:
        """The exact medoid of the *current* set — bit-for-bit the
        ``(index, energy, certificate)`` a fresh pipelined solve would
        return. Cheap when clean; on churn, repairs lazily."""
        if self.n == 0:
            raise ValueError("query on an empty MedoidIndex")
        if not self._dirty and self._last is not None:
            return self._last
        tracer = trace if trace is not None else self.trace
        if self.n == 1:
            self._commit_result(MedoidResult(0, 0.0, 0, 0, 0),
                                fresh=False)
            return self._last
        if self.n < TINY_N or not self._computed.any():
            return self._full_resolve(trace=tracer)
        return self._repair(tracer)

    def _repair(self, tracer) -> MedoidResult:
        n = self.n
        pend_ops, pend_elems = self._pending_ops, self._pending_elements
        e_cl, m_cl, n_cand = self._elect()
        if float(e_cl) <= 0.0:
            # zero incumbent energy: relative margins are vacuous
            # (all-duplicate degeneracy) — the fallback is the oracle
            return self._full_resolve(trace=tracer)
        comp = self._computed
        margin = _margin(n)
        l_defl = np.maximum((self._L / n) * (1.0 - margin), 0.0) \
            .astype(np.float32)
        invalid = np.logical_and(~comp, l_defl < e_cl)
        n_invalid = int(invalid.sum())

        engine_res = None
        if n_invalid:
            l_in = l_defl.copy()
            l_in[comp] = (self._S[comp] / n).astype(np.float32)
            ck_dir = self._repair_dir()
            # on any failure the ledgers stay untouched: a killed repair
            # retries the exact same election and resumes its (per-epoch
            # fingerprinted) checkpoint bit-identically
            engine_res, final = resume_with_survivors(
                self.X, l_in, comp, e_cl, m_cl,
                self._S.astype(np.float32),
                block=self.block, metric=self.metric,
                ladder_min=self.ladder_min,
                use_kernels=self.use_kernels,
                interpret=self.interpret,
                checkpoint=str(ck_dir) if ck_dir else None,
                resume="auto",
                fingerprint_extra={"epoch": self._epoch},
                trace=tracer,
                repair_info={"op": "churn", "ops": pend_ops,
                             "repaired": n, "invalidated": n_invalid})
            if ck_dir:
                shutil.rmtree(ck_dir, ignore_errors=True)
            newly = np.logical_and(~final["alive"], ~comp)
            self._S[newly] = final["esum"][newly].astype(np.float64)
            self._computed = np.logical_or(comp, newly)
            keep = ~self._computed
            self._L[keep] = np.maximum(
                self._L[keep], n * final["l"][keep].astype(np.float64))
            self.stats["elements_engine"] += engine_res.n_computed
            self.stats["elements_total"] += engine_res.n_computed
            # final election: the engine's incumbent keeps the *first*
            # strict improver, but a repaired row can tie the cached
            # winner at a lower index — fresh solves resolve such ties
            # lowest-index-first, so re-elect over the updated cache
            e_cl2, m_cl2, n_cand2 = self._elect()
            e_cl, m_cl, n_cand = e_cl2, m_cl2, n_cand + n_cand2
        self.stats["repairs"] += 1
        self.stats["invalidated"] += n_invalid
        if self._mx:
            self._mx["repairs"].inc()
            self._mx["invalidated"].inc(n_invalid)

        d1 = max(n - 1, 1)
        elements = (pend_elems + float(n_cand)
                    + (engine_res.n_computed if engine_res else 0))
        certified = engine_res.certified if engine_res else True
        res = MedoidResult(
            m_cl, float(e_cl) * n / d1, int(round(elements)),
            engine_res.n_rounds if engine_res else 0,
            int(round(elements * n)),
            n_stages=engine_res.n_stages if engine_res else 0,
            certified=certified,
            halt_reason=engine_res.halt_reason if engine_res else "")
        self._plan_repair(pend_ops, n_invalid, elements)
        self._commit_result(res, fresh=False,
                            per_op=elements / max(pend_ops, 1))
        return res

    # ------------------------------------------------------- full solve
    def _full_resolve(self, trace=None) -> MedoidResult:
        """The fallback AND the initial build: one full pipelined solve
        with checkpoint capture, ledgers rebuilt from its final
        ``SolveState``."""
        n = self.n
        pend_ops = self._pending_ops
        pend_elems = self._pending_elements
        if n == 1:
            self._S[:] = 0.0
            self._L[:] = 0.0
            self._computed[:] = True
            res = MedoidResult(0, 0.0, 1, 0, 1)
            self._commit_result(res, fresh=True)
            return self._last
        ck_dir = self._repair_dir()
        tmp = None
        if ck_dir is None:
            tmp = tempfile.mkdtemp(prefix="repro_stream_")
            ck_dir = Path(tmp)
        try:
            res = _trimed_pipelined(
                self.X, block=self.block, metric=self.metric,
                ladder_min=self.ladder_min, use_kernels=self.use_kernels,
                interpret=self.interpret, checkpoint=str(ck_dir),
                resume="auto", trace=trace)
            _, leaves, meta = Checkpointer(str(ck_dir)).load()
            st = SolveState.from_leaves(leaves,
                                        meta["extra"]["solve_state"])
        finally:
            if tmp is not None:
                shutil.rmtree(tmp, ignore_errors=True)
        if tmp is None:
            shutil.rmtree(ck_dir, ignore_errors=True)
        self._ledgers_from_state(st)
        self.stats["full_resolves"] += 1
        self.stats["elements_engine"] += res.n_computed
        self.stats["elements_total"] += res.n_computed
        if self._mx:
            self._mx["resolves"].inc()
        elements = pend_elems + res.n_computed
        self._plan_repair(pend_ops, -1, elements)
        self._commit_result(res, fresh=True,
                            per_op=(elements / pend_ops
                                    if pend_ops else None))
        return self._last

    def _ledgers_from_state(self, st: SolveState) -> None:
        n = self.n
        e_cl = float(np.asarray(st.e_cl))
        esum = np.asarray(st.esum, np.float32)
        l_st = np.asarray(st.l)
        alive = np.asarray(st.alive, bool)
        computed = np.zeros(n, bool)
        if int(st.phase) == PHASE_FULL:
            computed = ~alive
            L = n * l_st.astype(np.float64)
        else:
            # ladder state: l/alive cover only the survivor buffer.
            # Rows compacted away were either computed (their energy is
            # in esum) or eliminated against an incumbent that has only
            # tightened since — so e_cl itself is their valid bound.
            L = np.full(n, n * max(e_cl, 0.0))
            slot = np.isfinite(l_st)
            sidx = np.asarray(st.surv_idx)[slot]
            L[sidx] = n * l_st[slot].astype(np.float64)
            computed[sidx] = ~alive[slot]
        computed |= esum != 0          # computed rows dropped at compaction
        S = np.where(computed, esum.astype(np.float64), 0.0)
        self._S = S
        self._L = np.where(computed, S, np.maximum(L, 0.0))
        self._computed = computed

    # --------------------------------------------------------- plumbing
    def _repair_dir(self) -> Path | None:
        """Per-epoch repair checkpoint subdir (stale epochs pruned — a
        crash mid-repair followed by more churn must not resurrect a
        state fingerprinted for the older ledger)."""
        if self.checkpoint is None:
            return None
        d = self.checkpoint / f"repair_{self._epoch}"
        for stale in self.checkpoint.glob("repair_*"):
            if stale != d:
                shutil.rmtree(stale, ignore_errors=True)
        return d

    def _plan_repair(self, pend_ops, n_invalid, elements) -> None:
        from repro.api.planner import plan_repair
        self.last_plan = plan_repair(
            self.X, metric=self.metric, block=self.block,
            pending_ops=pend_ops, invalidated=n_invalid,
            elements=float(elements))

    def _commit_result(self, res: MedoidResult, *, fresh: bool,
                       per_op: float | None = None) -> None:
        self._last = res
        self._dirty = False
        self._pending_ops = 0
        self._pending_elements = 0.0
        if self._mx:
            self._mx["elements"].inc(
                res.n_computed, path="resolve" if fresh else "repair")
            if per_op is not None:
                self._mx["elements_per_op"].observe(per_op)
