"""Recompute roofline terms in results/dryrun.json from stored raw
numbers (no recompilation) — used after refining the roofline model."""
import json
import sys
from pathlib import Path

from repro.configs.base import SHAPES, get_config
from repro.roofline.analysis import roofline_terms


def main(path="results/dryrun.json"):
    p = Path(path)
    res = json.loads(p.read_text())
    for k, v in res.items():
        if v.get("status") != "ok":
            continue
        cfg = get_config(v["arch"])
        shape = SHAPES[v["shape"]]
        v["roofline"] = roofline_terms(cfg, shape, v)
    p.write_text(json.dumps(res, indent=1))
    print(f"refreshed {sum(1 for v in res.values() if v['status']=='ok')} cells")


if __name__ == "__main__":
    main(*sys.argv[1:])
