"""Roofline analysis: three terms per (arch x shape x mesh) cell.

    compute_s    = HLO_FLOPs(per device)      / peak_FLOP/s
    memory_s     = HLO_bytes(per device)      / HBM_bw
    collective_s = collective_bytes(per dev)  / link_bw

``cost_analysis()`` on the compiled SPMD artifact reports *per-device*
numbers (the partitioned module), so per-chip peaks divide directly.
Collective bytes come from parsing the compiled HLO: we sum operand
bytes of all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute (sync and async-start forms; ragged-all-to-all
included).

MODEL_FLOPS uses 6*N*D for training (2 fwd + 4 bwd) and 2*N*D for
inference, with N = active non-embedding params (MoE: router + shared +
top_k/E of routed experts). The ratio MODEL_FLOPS / HLO_FLOPs flags
remat/dispatch/padding waste.
"""
from __future__ import annotations

import math
import re

from repro.launch.mesh import HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1,
    "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "ragged-all-to-all",
)

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|\w+\[[0-9,]*\][^\s]*)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _shape_bytes(dtype: str, dims: str) -> int:
    nb = _DTYPE_BYTES.get(dtype)
    if nb is None:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * nb


def _shape_str_bytes(shape_str: str) -> int:
    """Bytes of an HLO shape string (handles tuple shapes)."""
    return sum(_shape_bytes(dt, dims)
               for dt, dims in _SHAPE_RE.findall(shape_str))


def collective_bytes(hlo_text: str) -> dict:
    """Sum per-device operand bytes of every collective instruction.

    Compiled HLO prints operands as bare ``%name`` references, so we
    first build a symbol table name -> result-shape bytes from every
    instruction definition in the module (all computations), then sum
    looked-up operand sizes; unknown operands fall back to the
    collective's own result shape.
    """
    symbols: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            symbols[m.group(1)] = _shape_str_bytes(m.group(2))

    by_kind: dict[str, float] = {}
    total = 0
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if " = " not in stripped:
            continue
        lhs, rhs = stripped.split(" = ", 1)
        for kind in _COLLECTIVES:
            m = re.search(rf"\b{kind}(?:-start)?\(", rhs)
            if not m:
                continue
            # operand list: up to the first `)` after the opname
            operand_str = rhs[m.end(): rhs.find(")", m.end())]
            nbytes = sum(symbols.get(name, 0)
                         for name in _OPERAND_RE.findall(operand_str))
            if nbytes == 0:  # fall back to the result shape
                nbytes = _shape_str_bytes(rhs.split(kind)[0])
            by_kind[kind] = by_kind.get(kind, 0) + nbytes
            total += nbytes
            break
    return {"total": total, "by_kind": by_kind}


# ---------------------------------------------------------------------------
# parameter accounting
# ---------------------------------------------------------------------------
def _tree_param_count(tree, skip_names=("embed",)):
    import jax

    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        names = [p.key for p in path if hasattr(p, "key")]
        if names and names[-1] in skip_names:
            continue
        total += math.prod(leaf.shape)
    return total


def active_param_count(cfg) -> tuple[int, int]:
    """(total_non_embed, active_non_embed). MoE: routed experts count
    ``top_k / n_experts`` of their weights toward active."""
    from repro.launch.specs import params_struct

    tree = params_struct(cfg)
    total = _tree_param_count(tree)
    if cfg.family != "moe":
        return total, total
    import jax

    expert = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        names = [p.key for p in path if hasattr(p, "key")]
        if (len(names) >= 3 and names[1] == "ffn"
                and names[-1] in ("w_gate", "w_up", "w_down")
                and "shared" not in names):
            expert += math.prod(leaf.shape)
    frac = cfg.moe.top_k / cfg.moe.n_experts
    active = total - expert + int(expert * frac)
    return total, active


def model_flops(cfg, shape, n_chips: int) -> float:
    """Useful (paper-counting) FLOPs per device for the cell."""
    _, active = active_param_count(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        per_token = 6 * active
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        per_token = 2 * active
    else:  # decode: one token per sequence
        tokens = shape.global_batch
        per_token = 2 * active
    return per_token * tokens / n_chips


def min_serve_bytes(cfg, shape, n_chips: int) -> float:
    """Ideal per-chip HBM traffic for one decode step: every (active)
    parameter byte + every cache byte must stream through HBM once —
    the bandwidth floor that defines the decode roofline."""
    import jax

    from repro.launch.specs import cache_struct, params_struct

    p_bytes = sum(math.prod(l.shape) * l.dtype.itemsize
                  for l in jax.tree.leaves(params_struct(cfg)))
    if cfg.family == "moe":
        total, active = active_param_count(cfg)
        p_bytes *= active / max(total, 1)
    c_bytes = sum(math.prod(l.shape) * l.dtype.itemsize
                  for l in jax.tree.leaves(
                      cache_struct(cfg, shape.global_batch, shape.seq_len)))
    return (p_bytes + c_bytes) / n_chips


def kernel_roofline(flops: float, nbytes: float, elapsed_s: float,
                    peak_flops: float = PEAK_FLOPS_BF16,
                    hbm_bw: float = HBM_BW) -> dict:
    """Place one kernel invocation (or an aggregate of them) on the
    machine roofline from its analytic FLOP/byte model and measured
    wall-clock (:mod:`repro.obs.profile` feeds this). ``compute_s`` /
    ``memory_s`` are the two roofline floors; ``bound`` names the
    higher one; ``roofline_fraction`` is floor-time / measured-time
    (dispatch overhead drives it toward 0 on the interpret path)."""
    compute_s = flops / peak_flops
    memory_s = nbytes / hbm_bw
    bound = "compute" if compute_s >= memory_s else "memory"
    achieved_flops = flops / elapsed_s if elapsed_s > 0 else 0.0
    achieved_bw = nbytes / elapsed_s if elapsed_s > 0 else 0.0
    floor = max(compute_s, memory_s)
    frac = floor / elapsed_s if elapsed_s > 0 else 0.0
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "bound": bound,
        "achieved_flops": achieved_flops,
        "achieved_bw": achieved_bw,
        "arithmetic_intensity": flops / nbytes if nbytes else 0.0,
        "roofline_fraction": round(frac, 6),
    }


def roofline_terms(cfg, shape, rec: dict) -> dict:
    flops = rec.get("flops") or 0.0
    nbytes = rec.get("bytes_accessed") or 0.0
    coll = rec.get("collective_bytes") or 0.0
    n_chips = rec.get("n_chips", 1)

    compute_s = flops / PEAK_FLOPS_BF16
    memory_s = nbytes / HBM_BW
    collective_s = coll / ICI_BW_PER_LINK
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)

    mf = model_flops(cfg, shape, n_chips)
    useful = mf / flops if flops else 0.0
    # ideal time: compute roofline for train/prefill; decode is
    # bandwidth-bound by construction, so its floor is param+cache
    # streaming time (whichever roofline is higher binds)
    t_bound = max(terms.values())
    ideal_s = mf / PEAK_FLOPS_BF16
    if shape.kind == "decode":
        ideal_s = max(ideal_s, min_serve_bytes(cfg, shape, n_chips) / HBM_BW)
    frac = ideal_s / t_bound if t_bound > 0 else 0.0
    return {
        **{k: round(v, 6) for k, v in terms.items()},
        "dominant": dominant.replace("_s", ""),
        "model_flops_per_chip": mf,
        "useful_flop_ratio": round(useful, 4),
        "roofline_fraction": round(frac, 4),
    }
